"""Crash-anywhere replay oracle for the event-sourced control plane.

The universal correctness property (DESIGN.md §12):

    snapshot + replay(log suffix)  ==  uninterrupted run

Every test here is some instantiation of that equation.  The harness runs a
seeded churn trace twice — once uninterrupted, once with a
:class:`~repro.stream.eventlog.FaultInjector` killing the engine at a chosen
fault point — then rebuilds the crashed engine from its durable log +
newest snapshot (``recover``), resumes it, and asserts the two runs are
byte-identical: trial sequences, processed-event streams, telemetry
aggregates (including final regret), and per-device accounting.

The acceptance sweep (``test_crash_anywhere_devplane_acceptance``) does
this at every stride-sampled event index of a 200+-event device-churn
trace; set ``FAULT_EVENTS=all`` to kill/restore at *every* processed event
(the nightly CI knob).  On divergence the harness writes a JSON artifact
(``first_divergence`` record + both fingerprints) under
``$REPLAY_ARTIFACT_DIR`` (default ``replay_divergence/``) — the file CI
uploads on failure.

Fuzzed interleavings of tenant + device churn live in
tests/test_eventlog_property.py (hypothesis).
"""

import dataclasses
import json
import os
from pathlib import Path

import numpy as np
import pytest

from conftest import random_psd
from repro.core.control_plane import ControlPlane
from repro.core.fleet import Fleet
from repro.devplane import AutoscalePolicy, DevPlaneEngine
from repro.stream import (
    ChurnTrace,
    DeviceJoin,
    DeviceLeave,
    DevicePreempt,
    EventLog,
    FaultInjector,
    MeshShrink,
    SimulatedCrash,
    SliceFail,
    StreamEngine,
    TenantArrive,
    TenantDepart,
    TrialHang,
    TrialPoison,
    device_churn_trace,
    first_divergence,
    poisson_churn_trace,
    recover,
)
from repro.stream.eventlog import deserialize_event, serialize_event


# ---- harness -----------------------------------------------------------------

def fingerprint(eng, res) -> dict:
    """Everything the oracle compares.  ``decisions``/``decision_seconds``
    are deliberately absent: they include wall-clock timing and decisions
    re-made during replay, the only engine state outside the oracle."""
    return {
        "trials": [dataclasses.astuple(t) for t in res.trials],
        "end_time": res.end_time,
        "event_index": eng.event_index,
        "policy_launches": res.policy_launches,
        "compaction_moves": res.compaction_moves,
        "compaction_move_counts": list(eng.compaction_move_counts),
        "summary": res.telemetry.summary(),
        "per_tenant": res.telemetry.per_tenant(),
        "per_device": res.telemetry.per_device(),
    }


def write_divergence_artifact(context: str, divergence, fp_ref, fp_got) -> Path:
    """The replay-divergence artifact CI uploads on failure."""
    root = Path(os.environ.get("REPLAY_ARTIFACT_DIR", "replay_divergence"))
    root.mkdir(parents=True, exist_ok=True)
    path = root / f"{context}.json"
    path.write_text(json.dumps(
        {"context": context, "first_divergence": divergence,
         "fingerprint_reference": fp_ref, "fingerprint_replayed": fp_got},
        indent=1, default=str))
    return path


def crash_and_recover(make_engine, trace, crash_index: int, point: str,
                      workdir: Path, *, snapshot_every: int | None = 8):
    """Kill a durable run at (``point``, ``crash_index``), recover from the
    log + snapshots, resume to completion.  Returns ``(engine, result,
    prefix, resumed_from)`` where ``prefix`` is the pre-crash processed
    records the resumed engine did not re-handle."""
    tag = f"{point}_{crash_index}"
    logdir = workdir / f"log_{tag}"
    snapdir = workdir / f"snap_{tag}"
    eng = make_engine(log=EventLog(logdir), snapshot_root=str(snapdir),
                      snapshot_every=snapshot_every,
                      fault=FaultInjector(crash_index, point))
    with pytest.raises(SimulatedCrash):
        eng.run(trace)
    eng.log.close()
    durable = EventLog.load(logdir)
    eng2, resumed_from = recover(make_engine, str(snapdir), durable)
    res2 = eng2.resume()
    prefix = [r for r in durable.processed if r[0] <= resumed_from]
    return eng2, res2, prefix, resumed_from


def assert_replay_matches(ref_eng, ref_res, rec_eng, rec_res, prefix,
                          context: str) -> None:
    got_processed = prefix + [tuple(r) for r in rec_eng.log.processed]
    div = first_divergence(ref_eng.log.processed, got_processed)
    fp_ref = fingerprint(ref_eng, ref_res)
    fp_got = fingerprint(rec_eng, rec_res)
    if div is not None or fp_ref != fp_got:
        path = write_divergence_artifact(context, div, fp_ref, fp_got)
        pytest.fail(f"replay diverged from the uninterrupted run "
                    f"({context}); artifact written to {path}")


def crash_indices(n_events: int) -> list[int]:
    """Which processed-event indices to kill at.  ``FAULT_EVENTS=all``
    (nightly CI) sweeps every index; the default stride-samples ~12 plus
    the endpoints, so the tier-1 lane stays fast without going blind to
    either end of the trace."""
    if os.environ.get("FAULT_EVENTS", "") == "all":
        return list(range(1, n_events + 1))
    stride = max(1, n_events // 10)
    picked = set(range(1, n_events + 1, stride))
    picked.update((1, 2, n_events // 2, max(n_events - 1, 1), n_events))
    return sorted(i for i in picked if 1 <= i <= n_events)


def run_reference(make_engine, trace):
    eng = make_engine()
    res = eng.run(trace)
    return eng, res


# ---- engine configurations under test ----------------------------------------

def stream_factory(**cfg):
    """Zero-arg-callable engine factory (recover() rebuilds configuration
    from code, not from the log) that also accepts per-run kwargs (log /
    snapshot / fault).  A fresh Fleet per engine — the fleet is mutated."""
    def make(**kw):
        return StreamEngine(Fleet.partition_pod(16 * 4, 4), "mdmt",
                            seed=0, max_live_models=60, num_shards=2,
                            **cfg, **kw)
    return make


def devplane_factory(**cfg):
    def make(**kw):
        return DevPlaneEngine(Fleet.partition_pod(16 * 6, 6), "mdmt",
                              seed=0, max_live_models=40, num_shards=2,
                              assign="batched", **cfg, **kw)
    return make


# ---- event (de)serialization -------------------------------------------------

def test_event_serialization_round_trip(rng):
    m = 5
    events = [
        TenantArrive(at=0.25, tenant_key=7, K_block=random_psd(rng, m, 0.04),
                     mu0=rng.standard_normal(m), cost=rng.uniform(0.5, 2, m),
                     z_true=rng.standard_normal(m)),
        TenantDepart(at=1.5, tenant_key=7),
        SliceFail(at=2.0, slice_id=3, downtime=5.5),
        DeviceJoin(at=3.0, chips=8, speed=1.75, cls="fast"),
        DeviceLeave(at=4.0, slice_id=1),
        DevicePreempt(at=5.0, slice_id=2),
        TrialHang(at=6.0, slice_id=0),
        TrialPoison(at=7.0, slice_id=3),
        MeshShrink(at=8.0, num_shards=2),
    ]
    for ev in events:
        # through an actual JSON round trip: repr-based floats must be exact
        back = deserialize_event(json.loads(json.dumps(serialize_event(ev))))
        assert type(back) is type(ev)
        for f in dataclasses.fields(ev):
            a, b = getattr(ev, f.name), getattr(back, f.name)
            if isinstance(a, np.ndarray):
                assert np.array_equal(a, b), f.name
            else:
                assert a == b, f.name


def test_event_serialization_rejects_unknown():
    with pytest.raises(TypeError):
        serialize_event(object())
    with pytest.raises(TypeError):
        deserialize_event({"type": "Nope", "at": 0.0})


def test_eventlog_durable_write_through_and_load(tmp_path):
    trace = poisson_churn_trace(num_sessions=4, seed=1, m_min=2, m_max=6)
    log = EventLog(tmp_path / "log")
    log.set_meta(trace_name=trace.name)
    for ev in trace:
        log.append_external(ev)
    log.append_processed(1, 0.5, "arrive", [0])
    log.append_processed(2, 0.75, "finish", [3, 10, 0])
    log.close()

    back = EventLog.load(tmp_path / "log")
    assert back.meta["trace_name"] == trace.name
    assert [serialize_event(e) for e in back.external_events()] == \
           [serialize_event(e) for e in trace]
    assert [list(r) for r in back.processed] == \
           [[1, 0.5, "arrive", [0]], [2, 0.75, "finish", [3, 10, 0]]]


def test_eventlog_schema_version_guard(tmp_path):
    log = EventLog(tmp_path / "log")
    log.close()
    meta = json.loads((tmp_path / "log" / "meta.json").read_text())
    meta["schema_version"] = 99
    (tmp_path / "log" / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="schema_version"):
        EventLog.load(tmp_path / "log")


def test_first_divergence():
    a = [(1, 0.5, "arrive", [0]), (2, 1.0, "depart", [0])]
    assert first_divergence(a, [tuple(r) for r in a]) is None
    b = [a[0], (2, 1.0, "depart", [1])]
    assert first_divergence(a, b) == {"offset": 1, "a": list(a[1]),
                                      "b": list(b[1])}
    d = first_divergence(a, a[:1])
    assert d["offset"] == 1 and d["b"] is None
    assert (d["len_a"], d["len_b"]) == (2, 1)


def test_fault_injector_fires_once_at_matching_point():
    fi = FaultInjector(crash_index=3, point="before")
    fi.check("after", 5)            # wrong point: never fires
    fi.check("before", 2)           # too early
    with pytest.raises(SimulatedCrash):
        fi.check("before", 4)       # first match at/after the index
    fi.check("before", 5)           # fired once; engine replays freely


# ---- crash-anywhere: base streaming engine -----------------------------------

def test_crash_anywhere_stream_engine(tmp_path):
    trace = poisson_churn_trace(num_sessions=12, arrival_rate=1.0, seed=4,
                                m_min=2, m_max=10, session_scale=15.0,
                                num_failure_slices=2)
    make = stream_factory(compact_every=2)
    ref_eng, ref_res = run_reference(make, trace)
    n = ref_eng.event_index
    assert n > 40
    for idx in crash_indices(n):
        out = crash_and_recover(make, trace, idx, "before", tmp_path)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"stream_before_{idx}")
    # the post-handler point too (crash after the log append, pre-snapshot)
    for idx in (1, n // 2, n):
        out = crash_and_recover(make, trace, idx, "after", tmp_path)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"stream_after_{idx}")


def test_crash_anywhere_policies_with_rng(tmp_path):
    """random / round_robin draw from the ControlPlane's Generator — the
    bit-generator state must survive snapshot + replay."""
    trace = poisson_churn_trace(num_sessions=8, seed=5, m_min=2, m_max=8,
                                session_scale=12.0)
    for policy in ("random", "round_robin"):
        def make(**kw):
            return StreamEngine(Fleet.partition_pod(16 * 3, 3), policy,
                                seed=11, max_live_models=40, **kw)
        ref_eng, ref_res = run_reference(make, trace)
        n = ref_eng.event_index
        for idx in (2, n // 2, n - 1):
            out = crash_and_recover(make, trace, idx, "before",
                                    tmp_path / policy)
            assert_replay_matches(ref_eng, ref_res, *out[:3],
                                  context=f"{policy}_before_{idx}")


def test_crash_mid_compact_and_mid_launch(tmp_path):
    """The torn-write points: after the control plane relocated blocks but
    before the engine remapped its queues, and after ``record_start`` but
    before the trial / completion event exists."""
    trace = poisson_churn_trace(num_sessions=12, arrival_rate=1.2, seed=4,
                                m_min=2, m_max=10, session_scale=10.0)
    make = stream_factory(compact_every=1)
    ref_eng, ref_res = run_reference(make, trace)
    assert sum(ref_eng.compaction_move_counts) > 0, \
        "trace must actually relocate blocks for mid_compact to bite"
    n = ref_eng.event_index
    for point in ("mid_compact", "mid_launch"):
        for idx in (1, n // 3):
            out = crash_and_recover(make, trace, idx, point, tmp_path)
            assert_replay_matches(ref_eng, ref_res, *out[:3],
                                  context=f"{point}_{idx}")


def test_recover_from_genesis_without_snapshots(tmp_path):
    """snapshot_every=None writes nothing: recovery must replay the whole
    log from genesis and still match."""
    trace = poisson_churn_trace(num_sessions=8, seed=2, m_min=2, m_max=8,
                                session_scale=12.0)
    make = stream_factory(compact_every=2)
    ref_eng, ref_res = run_reference(make, trace)
    idx = ref_eng.event_index // 2
    out = crash_and_recover(make, trace, idx, "before", tmp_path,
                            snapshot_every=None)
    eng2, res2, prefix, resumed_from = out
    assert resumed_from == 0 and prefix == []
    assert_replay_matches(ref_eng, ref_res, eng2, res2, prefix,
                          context=f"genesis_{idx}")


def test_recover_falls_back_past_corrupt_snapshot(tmp_path):
    """A torn newest snapshot (the crash-mid-save case the atomic publish
    makes rare but an operator can still produce) must not poison recovery:
    ``recover`` falls back to the next older readable step, or genesis."""
    trace = poisson_churn_trace(num_sessions=8, seed=2, m_min=2, m_max=8,
                                session_scale=12.0)
    make = stream_factory(compact_every=2)
    ref_eng, ref_res = run_reference(make, trace)
    n = ref_eng.event_index

    tag = f"before_{n - 1}"
    eng = make(log=EventLog(tmp_path / f"log_{tag}"),
               snapshot_root=str(tmp_path / f"snap_{tag}"), snapshot_every=4,
               fault=FaultInjector(n - 1, "before"))
    with pytest.raises(SimulatedCrash):
        eng.run(trace)
    eng.log.close()
    snaps = sorted((tmp_path / f"snap_{tag}").glob("step_*"))
    assert len(snaps) >= 2
    (snaps[-1] / "arrays.npz").write_bytes(b"not a zipfile")

    durable = EventLog.load(tmp_path / f"log_{tag}")
    eng2, resumed_from = recover(make, str(tmp_path / f"snap_{tag}"), durable)
    assert resumed_from == int(snaps[-2].name.split("_")[1])
    res2 = eng2.resume()
    prefix = [r for r in durable.processed if r[0] <= resumed_from]
    assert_replay_matches(ref_eng, ref_res, eng2, res2, prefix,
                          context="corrupt_snapshot_fallback")


# ---- crash-anywhere: the acceptance sweep (elastic device plane) -------------

def test_crash_anywhere_devplane_acceptance(tmp_path):
    """The headline acceptance gate: a 200+-external-event seeded trace
    with tenant churn AND device churn (joins/leaves/preemptions), killed
    and restored at every stride-sampled processed-event index (every
    index under ``FAULT_EVENTS=all``), reproduces the uninterrupted run's
    trial sequence, telemetry, and final regret exactly."""
    trace = device_churn_trace(num_sessions=100, arrival_rate=1.4, seed=3,
                               initial_slices=6, join_rate=0.10,
                               leave_rate=0.06, preempt_rate=0.06,
                               m_min=2, m_max=8, session_scale=10.0)
    assert trace.num_events >= 200, trace.num_events
    make = devplane_factory(compact_every=3)
    ref_eng, ref_res = run_reference(make, trace)
    n = ref_eng.event_index
    assert n >= trace.num_events
    summary = ref_res.telemetry.summary()
    assert summary["tenant_regret_max"] is not None
    assert summary["devices_joined"] > 0 and summary["devices_left"] > 0

    for idx in crash_indices(n):
        out = crash_and_recover(make, trace, idx, "before", tmp_path,
                                snapshot_every=16)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"devplane_before_{idx}")


def test_crash_anywhere_devplane_autoscale(tmp_path):
    """Autoscale adds engine-private state (cooldown clock, join/leave
    counters) — the _snapshot_extra/_restore_extra hooks under crash."""
    trace = device_churn_trace(num_sessions=14, arrival_rate=1.5, seed=7,
                               initial_slices=3, join_rate=0.05,
                               leave_rate=0.03, preempt_rate=0.04,
                               m_min=2, m_max=8, session_scale=10.0)
    make = devplane_factory(
        compact_every=2,
        autoscale=AutoscalePolicy(high_backlog=3.0, low_backlog=0.5,
                                  cooldown=5.0, max_devices=10))
    ref_eng, ref_res = run_reference(make, trace)
    n = ref_eng.event_index
    for idx in (1, n // 3, 2 * n // 3, n):
        out = crash_and_recover(make, trace, idx, "before", tmp_path)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"autoscale_before_{idx}")


# ---- incremental compaction (bounded relocations per decision) ---------------

def test_incremental_compaction_bounded_and_replayable(tmp_path):
    """``compact_max_moves`` turns the periodic stop-the-world pass into a
    bounded-work pass on every departure: each call relocates at most that
    many blocks, and the crash oracle holds across the incremental passes."""
    trace = poisson_churn_trace(num_sessions=12, arrival_rate=1.2, seed=4,
                                m_min=2, m_max=10, session_scale=10.0)
    make = stream_factory(compact_max_moves=1)
    ref_eng, ref_res = run_reference(make, trace)
    counts = ref_eng.compaction_move_counts
    assert len(counts) == ref_eng._departures   # a pass on EVERY departure
    assert counts and max(counts) <= 1
    assert sum(counts) > 0                      # and it does real work
    n = ref_eng.event_index
    for idx in (2, n // 2, n - 1):
        out = crash_and_recover(make, trace, idx, "before", tmp_path)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"incremental_before_{idx}")


# ---- compaction edge cases (control-plane level) -----------------------------

def _mk_cp(num_shards=2, seed=0):
    return ControlPlane(np.random.default_rng(seed), num_shards=num_shards)


def _add_tenant(cp, rng, m=4):
    K = random_psd(rng, m, 0.04)
    return cp.add_tenant(K, np.zeros(m), np.ones(m))


def test_compact_pins_blocks_with_in_flight_trials(rng):
    """A tenant with a launched-but-unfinished trial must never relocate:
    the pending completion event holds its global model id.  Build a
    two-shard plane, empty one shard, and check the pinned block stays put
    while an idle co-resident block moves."""
    cp = _mk_cp()
    handles = [_add_tenant(cp, rng) for _ in range(4)]
    span = cp._layout.shard_capacity
    by_shard: dict[int, list] = {}
    for h in handles:
        by_shard.setdefault(int(h.models[0]) // span, []).append(h)
    crowded = max(by_shard, key=lambda s: len(by_shard[s]))
    assert len(by_shard[crowded]) >= 2
    keep_busy, keep_idle = by_shard[crowded][:2]
    for h in handles:
        if h not in (keep_busy, keep_idle):
            cp.retire_tenant(h.tenant_id)
    cp.record_start(int(keep_busy.models[0]))

    remap = cp.compact(max_imbalance=1.0)
    assert keep_busy.tenant_id not in remap, "in-flight block relocated"
    assert keep_idle.tenant_id in remap, "idle block should rebalance"
    old_ids, new_ids = remap[keep_idle.tenant_id]
    assert cp.membership[keep_idle.tenant_id, new_ids].all()
    assert not cp.membership[keep_idle.tenant_id, old_ids].any()
    # and the pinned block is untouched
    assert cp.membership[keep_busy.tenant_id, keep_busy.models].all()


def test_compact_max_moves_bounds_each_pass(rng):
    """Incremental mode at the plane level: every call relocates at most
    ``max_moves`` blocks, repeated calls converge to the same fixpoint a
    full pass reaches in one go."""
    cp = _mk_cp(num_shards=4)
    handles = [_add_tenant(cp, rng, m=3) for _ in range(8)]
    span = cp._layout.shard_capacity
    # empty every shard but the fullest -> maximal imbalance
    by_shard: dict[int, list] = {}
    for h in handles:
        by_shard.setdefault(int(h.models[0]) // span, []).append(h)
    crowded = max(by_shard, key=lambda s: len(by_shard[s]))
    for shard, hs in by_shard.items():
        if shard != crowded:
            for h in hs:
                cp.retire_tenant(h.tenant_id)

    passes = 0
    while True:
        remap = cp.compact(max_imbalance=1.0, max_moves=1)
        if not remap:
            break
        assert len(remap) <= 1
        passes += 1
        assert passes < 50, "incremental compaction failed to converge"
    assert passes >= 1
    assert cp.compact(max_imbalance=1.0) == {}   # full pass agrees: done


def test_compaction_at_exact_departure_boundaries():
    """Engine-level boundary accounting: with ``compact_every=k`` a pass
    runs after exactly the k-th, 2k-th, ... *admitted* departure — a
    tenant that departs while still queued must not advance the counter."""
    r = np.random.default_rng(0)

    def arrive(key, at, m=3, cost=1.0):
        return TenantArrive(at=at, tenant_key=key,
                            K_block=random_psd(r, m, 0.04), mu0=np.zeros(m),
                            cost=np.full(m, cost),
                            z_true=r.standard_normal(m))

    events = [arrive(k, at=float(k)) for k in range(5)]
    # key 5 arrives over capacity and departs while queued
    events.append(arrive(5, at=4.5, m=30))
    events.append(TenantDepart(at=4.8, tenant_key=5))
    events += [TenantDepart(at=20.0 + k, tenant_key=k) for k in range(5)]
    trace = ChurnTrace(events=tuple(sorted(events, key=lambda e: e.at)),
                       name="boundary")

    for k, expected_passes in ((2, 2), (3, 1)):
        eng = StreamEngine(Fleet.partition_pod(16 * 2, 2), "mdmt", seed=0,
                           max_live_models=20, num_shards=2, compact_every=k)
        eng.run(trace)
        assert eng._departures == 5          # the queued depart didn't count
        assert len(eng.compaction_move_counts) == expected_passes == 5 // k


def test_pending_completion_for_departed_tenant_across_compaction(tmp_path):
    """The nastiest interleaving: tenant A departs while its long trial is
    in flight, the departure triggers a compaction that rebalances other
    blocks into/around A's freed span, a new tenant reuses A's slots, and
    only then does A's completion event fire.  The completion must resolve
    through the tenant key (rejected observation), never corrupt the new
    owner — and the whole dance must replay across a mid_compact crash."""
    r = np.random.default_rng(1)

    def arrive(key, at, m, cost):
        return TenantArrive(at=at, tenant_key=key,
                            K_block=random_psd(r, m, 0.04), mu0=np.zeros(m),
                            cost=np.full(m, float(cost)),
                            z_true=r.standard_normal(m))

    events = [
        arrive(0, 0.0, m=3, cost=50.0),       # A: trials outlive everything
        arrive(1, 0.2, m=3, cost=1.0),        # B: fast, becomes idle
        TenantDepart(at=2.0, tenant_key=0),   # A leaves mid-flight -> compact
        arrive(2, 3.0, m=3, cost=1.0),        # C: reuses A's freed slots
        TenantDepart(at=30.0, tenant_key=1),
        TenantDepart(at=60.0, tenant_key=2),
    ]
    trace = ChurnTrace(events=tuple(events), name="pending-completion")

    def make(**kw):
        return StreamEngine(Fleet.partition_pod(16 * 2, 2), "mdmt", seed=0,
                            max_live_models=20, num_shards=2,
                            compact_every=1, **kw)

    ref_eng, ref_res = run_reference(make, trace)
    tele = ref_res.telemetry
    # A's in-flight trials finished after its departure: discarded, counted
    assert tele.num_rejected_observations >= 1
    assert len(ref_eng.compaction_move_counts) == 3   # every departure
    # every *observed* trial's z matches its owner's ground truth through
    # the (tenant_key, local_model) pair — slot reuse never crossed wires
    arrives = {e.tenant_key: e for e in events
               if isinstance(e, TenantArrive)}
    observed = [t for t in ref_res.trials if t.z is not None]
    assert observed
    for t in observed:
        assert t.z == float(arrives[t.tenant_key].z_true[t.local_model])
    # and the interleaving replays across both torn-write points
    n = ref_eng.event_index
    for point in ("mid_compact", "before"):
        for idx in (1, n // 2):
            out = crash_and_recover(make, trace, idx, point, tmp_path,
                                    snapshot_every=4)
            assert_replay_matches(ref_eng, ref_res, *out[:3],
                                  context=f"pending_{point}_{idx}")


# ---- live health plane under crash (DESIGN.md §14) ---------------------------

def test_crash_recovery_reemits_alerts_forensics_and_export_windows(tmp_path):
    """The §14 replay contract: alert content, forensics records, and
    export-window timing are pure functions of the sim-time event stream,
    so for any crash point

        durable alert prefix (event_index <= snapshot step)
          + resumed run's re-emitted alerts  ==  uninterrupted run's alerts

    byte-for-byte, the resumed forensics records equal the uninterrupted
    run's suffix exactly, and the resumed exporter emits the identical
    (window, t, event_index) schedule for the suffix.  Detector state and
    the export cursor ride in the engine snapshot; the durable prefix lives
    in the crashed log's alerts.jsonl."""
    from repro.obs import (ForensicsRecorder, HealthMonitor, MetricsExporter,
                           MetricsRegistry)

    trace = poisson_churn_trace(num_sessions=10, arrival_rate=1.2, seed=6,
                                m_min=2, m_max=8, session_scale=12.0,
                                num_failure_slices=1)

    def factory(bag):
        def make(**kw):
            reg = MetricsRegistry()
            planes = dict(
                metrics=reg,
                exporter=MetricsExporter(reg, window=5.0),
                health=HealthMonitor(slo={"device_utilization": 1.5},
                                     window=5.0, burn_windows=2,
                                     stall_k=4, queue_limit=2),
                forensics=ForensicsRecorder())
            bag.append(planes)
            return StreamEngine(Fleet.partition_pod(16 * 3, 3), "mdmt",
                                seed=0, max_live_models=30, num_shards=2,
                                **planes, **kw)
        return make

    ref_bag = []
    ref_eng, ref_res = run_reference(factory(ref_bag), trace)
    ref_alerts = [a.to_record() for a in ref_bag[0]["health"].alerts]
    ref_forensics = ref_bag[0]["forensics"].records
    assert len(ref_alerts) >= 2, "trace must fire alerts for the test to bite"
    assert ref_forensics
    assert ref_eng.log.alerts == ref_alerts   # engine streams them durably

    def export_keys(records):
        return [(r["window"], r["t"], r["event_index"],
                 bool(r.get("final"))) for r in records]

    ref_exports = export_keys(ref_bag[0]["exporter"].records)
    n = ref_eng.event_index
    mid_alert_ev = ref_alerts[len(ref_alerts) // 2]["event_index"]
    for crash_at in sorted({2, mid_alert_ev + 1, n - 1}):
        bag = []
        make = factory(bag)
        workdir = tmp_path / f"c{crash_at}"
        logdir, snapdir = workdir / "log", workdir / "snap"
        eng = make(log=EventLog(logdir), snapshot_root=str(snapdir),
                   snapshot_every=5, fault=FaultInjector(crash_at, "before"))
        with pytest.raises(SimulatedCrash):
            eng.run(trace)
        eng.log.close()

        durable = EventLog.load(logdir)
        eng2, resumed_from = recover(make, str(snapdir), durable)
        res2 = eng2.resume()
        prefix = [r for r in durable.processed if r[0] <= resumed_from]
        assert_replay_matches(ref_eng, ref_res, eng2, res2, prefix,
                              context=f"obs_planes_before_{crash_at}")

        # alerts: durable prefix + re-emitted suffix == uninterrupted run
        alert_prefix = [a for a in durable.alerts
                        if a["event_index"] <= resumed_from]
        alert_suffix = [a.to_record() for a in bag[-1]["health"].alerts]
        assert alert_prefix + alert_suffix == ref_alerts
        # the resumed engine's own durable stream holds exactly the suffix
        assert eng2.log.alerts == alert_suffix

        # forensics: the resumed run re-emits the suffix byte-identically
        assert bag[-1]["forensics"].records == \
            [r for r in ref_forensics if r["event_index"] > resumed_from]

        # export windows: identical (window, t, event_index) schedule for
        # the suffix (content carries wall-clock histograms — not compared)
        assert export_keys(bag[-1]["exporter"].records) == \
            [k for k in ref_exports if k[2] > resumed_from]
    # the sweep must exercise both a non-empty prefix and non-empty suffix
    assert mid_alert_ev + 1 > 2 and n - 1 > mid_alert_ev
