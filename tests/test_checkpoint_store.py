"""Direct coverage for checkpoint/store.py (previously only exercised
indirectly through training-loop and engine-snapshot tests): round trips,
the atomic-publish layout, and — the recovery-critical part — that every
flavor of on-disk damage surfaces as :class:`CheckpointError`, the signal
``stream.eventlog.recover`` uses to fall back to an older step or genesis
instead of mis-restoring."""

import json

import numpy as np
import pytest

from repro.checkpoint import (
    SCHEMA_VERSION,
    CheckpointError,
    CheckpointManager,
    latest_step,
    load_arrays,
    load_checkpoint,
    save_checkpoint,
)


def _tree(rng):
    return {"w": rng.standard_normal((4, 3)),
            "opt": {"m": rng.standard_normal(5), "count": np.int64(7)},
            "mask": np.array([True, False, True])}


def _save(tmp_path, rng, step=3, meta=None):
    return save_checkpoint(tmp_path, step, _tree(rng),
                           meta if meta is not None else {"note": "hi"})


def test_round_trip_like_tree(tmp_path, rng):
    tree = _tree(rng)
    path = save_checkpoint(tmp_path, 3, tree, {"note": "hi"})
    assert path == tmp_path / "step_00000003"
    like = {"w": np.zeros((4, 3)),
            "opt": {"m": np.zeros(5), "count": np.int64(0)},
            "mask": np.zeros(3, bool)}
    back, meta = load_checkpoint(tmp_path, 3, like)
    assert meta == {"note": "hi"}
    np.testing.assert_array_equal(back["w"], tree["w"])
    np.testing.assert_array_equal(back["opt"]["m"], tree["opt"]["m"])
    assert int(back["opt"]["count"]) == 7
    np.testing.assert_array_equal(back["mask"], tree["mask"])


def test_round_trip_raw_arrays(tmp_path, rng):
    """load_arrays: the engine-snapshot path — no like_tree, exact bytes."""
    tree = _tree(rng)
    save_checkpoint(tmp_path, 5, tree, {"event_index": 41})
    arrays, meta = load_arrays(tmp_path, 5)
    assert meta == {"event_index": 41}
    assert set(arrays) == {"w", "opt/m", "opt/count", "mask"}
    np.testing.assert_array_equal(arrays["w"], tree["w"])
    assert arrays["w"].dtype == tree["w"].dtype


def test_missing_step_is_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_arrays(tmp_path, 1)
    with pytest.raises(FileNotFoundError):
        load_checkpoint(tmp_path, 1, {"w": np.zeros(2)})


def test_corrupt_arrays_rejected(tmp_path, rng):
    path = _save(tmp_path, rng)
    (path / "arrays.npz").write_bytes(b"this is not a zipfile")
    with pytest.raises(CheckpointError, match="corrupt arrays"):
        load_arrays(tmp_path, 3)


def test_truncated_arrays_rejected(tmp_path, rng):
    path = _save(tmp_path, rng)
    blob = (path / "arrays.npz").read_bytes()
    (path / "arrays.npz").write_bytes(blob[: len(blob) // 2])
    with pytest.raises(CheckpointError):
        load_arrays(tmp_path, 3)


def test_missing_manifest_rejected(tmp_path, rng):
    path = _save(tmp_path, rng)
    (path / "manifest.json").unlink()
    with pytest.raises(CheckpointError, match="no manifest"):
        load_arrays(tmp_path, 3)


def test_unparsable_manifest_rejected(tmp_path, rng):
    path = _save(tmp_path, rng)
    (path / "manifest.json").write_text("{truncated")
    with pytest.raises(CheckpointError, match="corrupt manifest"):
        load_arrays(tmp_path, 3)


def test_schema_version_mismatch_rejected(tmp_path, rng):
    path = _save(tmp_path, rng)
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["schema_version"] == SCHEMA_VERSION
    manifest["schema_version"] = SCHEMA_VERSION + 1
    (path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(CheckpointError, match="schema_version"):
        load_arrays(tmp_path, 3)


def test_arrays_missing_manifest_key_rejected(tmp_path, rng):
    """A manifest promising keys the npz lacks means a torn write slipped
    through — must be CheckpointError, not a KeyError deep in restore."""
    path = _save(tmp_path, rng)
    with np.load(path / "arrays.npz") as data:
        arrays = {k: data[k] for k in data.files}
    arrays.pop("w")
    np.savez(path / "arrays.npz", **arrays)
    with pytest.raises(CheckpointError, match="missing manifest keys"):
        load_arrays(tmp_path, 3)


def test_latest_step_ignores_tmp_dirs(tmp_path, rng):
    assert latest_step(tmp_path) is None
    _save(tmp_path, rng, step=3)
    _save(tmp_path, rng, step=7)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert latest_step(tmp_path) == 7


def test_manager_retention_and_restore_latest(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=2)
    like = _tree(np.random.default_rng(99))
    for step in (1, 2, 3):
        mgr.save(step, _tree(np.random.default_rng(step)),
                 {"step": step}, blocking=True)
    assert latest_step(tmp_path) == 3
    assert not (tmp_path / "step_00000001").exists()   # gc'd past keep=2
    step, tree, meta = mgr.restore_latest(like)
    assert step == 3 and meta == {"step": 3}
    np.testing.assert_array_equal(
        tree["w"], _tree(np.random.default_rng(3))["w"])
