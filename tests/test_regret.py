"""Regret integration (Section 3.2) + Theorem 2 bound sanity."""

import numpy as np

from repro.core import (
    final_regret,
    miu_cumulative_exact,
    regret_curves,
    simulate,
    synthetic_matern_problem,
)
from repro.core.scheduler import SimResult, TrialRecord
from repro.core.tenancy import Problem


def hand_problem():
    K = np.eye(2) * 0.25
    return Problem(
        K=K, mu0=np.zeros(2), z_true=np.array([1.0, 0.4]),
        cost=np.array([2.0, 1.0]), membership=np.array([[True, True]]),
        name="hand")


def test_cumulative_regret_step_integration():
    prob = hand_problem()
    # one tenant; observes model 1 (z=0.4) at t=1, model 0 (z=1.0) at t=3.
    trials = [
        TrialRecord(1, 0, 0, 0.0, 1.0, 0.4),
        TrialRecord(0, 0, 0, 1.0, 3.0, 1.0),
    ]
    res = SimResult(prob, "mdmt", 1, trials, 3.0, 2, 0.0)
    c = regret_curves(res)
    # worst-start clamp: z* - min z = 0.6 until t=1; then 1.0-0.4=0.6.. wait
    # min z in L = 0.4 so initial gap = 0.6; after t=1 best=0.4, gap 0.6;
    # after t=3 gap 0. Regret(3) = 0.6*1 + 0.6*2 = 1.8.
    assert abs(c.cumulative_at(3.0) - 1.8) < 1e-9
    assert abs(c.cumulative_at(2.0) - 1.2) < 1e-9
    assert c.time_to_instantaneous(0.0) == 3.0
    # beyond the last event, regret stays flat (gap 0)
    assert abs(c.cumulative_at(10.0) - 1.8) < 1e-9


def test_instantaneous_regret_monotone_nonincreasing():
    prob = synthetic_matern_problem(num_users=4, num_models_per_user=10, seed=0)
    res = simulate(prob, "mdmt", num_devices=2, seed=0)
    inst = regret_curves(res).instantaneous
    assert (np.diff(inst) <= 1e-12).all()


def test_theorem2_bound_holds_empirically():
    """Regret_T <= C * (MIU(T,K) + M) * (N^2 / M) * c_bar for a reasonable C.

    We check the bound *shape* with the paper's constants folded into C
    estimated from Assumption 1's R on the sampled instance.
    """
    prob = synthetic_matern_problem(num_users=4, num_models_per_user=6, seed=2)
    M = 2
    res = simulate(prob, "mdmt", num_devices=M, seed=0)
    T = res.end_time
    reg = final_regret(res, T)

    N = prob.num_users
    c_bar = np.mean([prob.cost[np.argmax(
        np.where(prob.membership[i], prob.z_true, -np.inf))] for i in range(N)])
    # per-tenant blocks are identical 6x6 Matérn matrices; MIU over the block
    miu = miu_cumulative_exact(prob.K[:6, :6], 6)
    bound_core = (miu + M) * N * N / M * c_bar
    # generous universal constant (the paper's \lesssim hides tau(R)/tau(-R)):
    assert reg <= 50.0 * bound_core


def test_average_regret_converges():
    """(1/T) Regret_T -> small once everything is observed (convergence claim)."""
    prob = synthetic_matern_problem(num_users=4, num_models_per_user=10, seed=1)
    res = simulate(prob, "mdmt", num_devices=2, seed=0)
    c = regret_curves(res)
    T_end = res.end_time
    assert c.cumulative_at(10 * T_end) / (10 * T_end) <= \
        c.cumulative_at(T_end) / T_end + 1e-9
    assert c.instantaneous[-1] < 1e-9
